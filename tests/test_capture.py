"""Whole-iteration capture: heterogeneous graphs, one dispatch per step.

Acceptance (ISSUE 7): one captured Jacobi iteration is exactly ONE
dispatch (engine counter AND traced launch counts), numerics are
identical to the eager path, two schedules of the same captured step
digest apart and never cross-serve executables, and calibration never
pools captured-step samples with pure-comm samples.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm import (CommConfig, CommSession, ComputeNode, StepCapture,
                        captured_psum)
from repro.comm.calibration import CalibrationFitter
from repro.comm.capture import BufferSpec, lower_step
from repro.comm.telemetry import DispatchSample, StageTimings
from repro.compat import shard_map
from repro.core.halo import jacobi_step, make_captured_jacobi_step


@pytest.fixture()
def sess(dev_mesh):
    return CommSession(mesh=dev_mesh)


def _count_eqns(fn, abstract_args, match):
    def count(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if match(eqn):
                total += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        total += count(sub.jaxpr)
                    elif isinstance(sub, jax.core.Jaxpr):
                        total += count(sub)
        return total
    return count(jax.make_jaxpr(fn)(*abstract_args).jaxpr)


# ------------------------- Jacobi acceptance --------------------------------

def test_captured_jacobi_one_dispatch_bitwise_eager(sess):
    """ONE captured Jacobi iteration == ONE dispatch, numerics identical
    to the eager ``jacobi_step`` (bitwise)."""
    n = sess.engine.num_devices
    rows, cols = 8, 12
    u = np.random.default_rng(0).random((n, rows, cols), dtype=np.float32)
    step = make_captured_jacobi_step(sess, rows, cols)
    (out,) = step(u)
    assert sess.stats()["dispatches"] == 1

    eager = shard_map(
        lambda x: jacobi_step(x[0], sess.axis_name)[None],
        mesh=sess.mesh, in_specs=P(sess.axis_name),
        out_specs=P(sess.axis_name), check_vma=False)
    ref = eager(jnp.asarray(u))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    # steady state: still one dispatch per iteration, served by fast path
    (out2,) = step(np.asarray(out))
    assert sess.stats()["dispatches"] == 2
    assert sess.stats()["fastpath"]["hits"] >= 1


def test_captured_jacobi_traced_launch_counts(sess):
    """Traced ppermute + kernel-call count == scheduled num_nodes: the
    compiled step program contains exactly the graph's copy nodes as
    ppermutes and its compute nodes as ``capk_*`` jit calls."""
    eng = sess.engine
    step = make_captured_jacobi_step(sess, 8, 12)
    entry = step.resolve()
    graph = entry.graph
    fn = eng._build_step_fn(entry.program, graph, entry.outputs)
    abstracts = eng._step_abstracts(entry.program)
    ppermutes = _count_eqns(
        fn, abstracts, lambda e: e.primitive.name == "ppermute")
    kernels = _count_eqns(
        fn, abstracts,
        lambda e: str(e.params.get("name", "")).startswith("capk_"))
    assert ppermutes == graph.num_copy_nodes
    assert kernels == graph.num_compute_nodes
    assert ppermutes + kernels == graph.num_nodes


def test_stats_and_describe_report_breakdown(sess):
    step = make_captured_jacobi_step(sess, 4, 8)
    step.resolve()
    g = sess.stats()["graph"]
    assert g["copy_nodes_compiled"] > 0
    assert g["compute_nodes_compiled"] == 2   # halo_slices + jacobi_sweep
    assert (g["nodes_compiled"]
            == g["copy_nodes_compiled"] + g["compute_nodes_compiled"])
    d = sess.describe(0, 1, 1 << 20, max_paths=2)
    assert d["graph"]["copy_nodes"] == d["graph"]["nodes"]
    assert d["graph"]["compute_nodes"] == 0


# ------------------------- schedules ----------------------------------------

def _multipath_build(cap):
    x = cap.input((1 << 20,), jnp.float32)
    y = cap.kernel(lambda v: v * 2.0, x, name="double")
    (r,) = cap.exchange([(y, 0, 1)], max_paths=2, num_chunks=4)
    return cap.kernel(lambda v: v + 1.0, r, name="inc")


def test_schedules_digest_apart_never_cross_serve(sess):
    """Two schedules of the SAME captured step digest apart: distinct
    plan-cache keys, distinct fast-path entries, no cross-serving."""
    s_rr = sess.capture(_multipath_build, schedule="round_robin")
    s_df = sess.capture(_multipath_build, schedule="depth_first")
    e_rr, e_df = s_rr.resolve(), s_df.resolve()
    assert e_rr.graph.num_copy_nodes > 4   # genuinely multipath
    assert e_rr.digest != e_df.digest
    assert e_rr.key != e_df.key
    assert sess.stats()["cache"]["size"] == 2
    # resolving again serves each schedule its own memoized entry
    assert s_rr.resolve().digest == e_rr.digest
    assert s_df.resolve().digest == e_df.digest


def test_cross_schedule_numerics_and_one_dispatch_each(sess):
    def build(cap):
        x = cap.input((4096,), jnp.float32)
        y = cap.kernel(lambda v: v * 3.0, x, name="triple")
        (r,) = cap.exchange([(y, 0, 1)], num_chunks=2)
        return cap.kernel(lambda v: v - 1.0, r, name="dec")

    n = sess.engine.num_devices
    x = np.random.default_rng(3).random((n, 4096), dtype=np.float32)
    outs = {}
    for sched in ("round_robin", "depth_first", "critical_path"):
        before = sess.stats()["dispatches"]
        (outs[sched],) = sess.capture(build, schedule=sched)(x)
        assert sess.stats()["dispatches"] == before + 1
    expect = x[0] * 3.0 - 1.0           # payload read on src device 0
    for sched, out in outs.items():
        np.testing.assert_array_equal(np.asarray(out[1]), expect)


# ------------------------- captured psum / train ----------------------------

def test_captured_psum_matches_sum(sess):
    n = sess.engine.num_devices
    x = np.arange(n * 16, dtype=np.float32).reshape(n, 16) + 1.0

    def build(cap):
        v = cap.input((16,), jnp.float32)
        return captured_psum(cap, v, n, name="ps")

    (out,) = sess.capture(build)(x)
    assert sess.stats()["dispatches"] == 1
    expect = x.sum(axis=0)
    for d in range(n):
        np.testing.assert_array_equal(np.asarray(out[d]), expect)


def test_captured_train_step_matches_eager_dp(dev_mesh):
    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticDataset
    from repro.optim import OptimConfig
    from repro.training import (TrainStepConfig, init_state,
                                make_captured_dp_train_step,
                                make_dp_train_step)

    cfg = dataclasses.replace(
        get_config("smollm_360m").reduced(), name="mini-cap",
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=128)
    opt = OptimConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    ts = TrainStepConfig()
    comm = CommSession(mesh=dev_mesh)
    state_a = init_state(cfg, opt)
    state_b = jax.tree.map(lambda x: x, state_a)
    ds = SyntheticDataset(cfg, DataConfig(seq_len=8, global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    eager = jax.jit(make_dp_train_step(cfg, ts, opt,
                                       CommSession(mesh=dev_mesh)))
    captured = make_captured_dp_train_step(cfg, ts, opt, comm, state_a,
                                           batch)
    state_a, ma = eager(state_a, batch)
    state_b, mb = captured(state_b, batch)
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-5, rtol=1e-4)
    # grad + (n-1) ring rounds + update, all ONE dispatch
    assert comm.stats()["dispatches"] == 1
    assert comm.stats()["graph"]["compute_nodes_compiled"] >= 3


# ------------------------- calibration isolation ----------------------------

def _sample(compute=(), launch_ns=20_000, execute_ns=100_000):
    routes = (((((0, 1),), 1 << 20, 4),),)
    return DispatchSample(
        routes=routes, nbytes=1 << 20, num_nodes=4, window=1,
        schedule="round_robin",
        stages=StageTimings(launch_ns=launch_ns, execute_ns=execute_ns),
        fastpath_hit=True, compute=compute)


def test_calibration_never_pools_captured_with_pure_comm():
    """Satellite 1: DispatchSample signatures include compute identity,
    and the fitter ignores captured-step samples entirely."""
    pure = _sample()
    captured = _sample(compute=(("jacobi_sweep", 480, 0),))
    assert pure.signature != captured.signature

    from repro.core.topology import Topology
    topo = Topology.full_mesh(4)
    fitter = CalibrationFitter(topo, min_samples=3, warmup=0)
    # only captured-step samples: nothing to fit from
    prof = fitter.fit([captured] * 6)
    assert prof.launch is None
    assert prof.link_bandwidth_gbps == {}
    # mixed: the fit must equal the pure-only fit
    mixed = fitter.fit([pure] * 6 + [captured] * 6)
    pure_only = fitter.fit([pure] * 6)
    assert (mixed.launch is None) == (pure_only.launch is None)
    if mixed.launch is not None:
        assert mixed.launch == pure_only.launch
    assert mixed.link_bandwidth_gbps == pure_only.link_bandwidth_gbps


# ------------------------- capture-surface contracts ------------------------

def test_capture_contracts():
    cap = StepCapture()
    x = cap.input((8,), jnp.float32)
    with pytest.raises(ValueError, match="name"):
        cap.kernel(lambda v: v, x)          # anonymous lambda
    y = cap.kernel(lambda v: v * 2, x, name="k")
    with pytest.raises(ValueError, match="identity"):
        cap.kernel(lambda v: v * 3, x, name="k")   # name reuse
    m = cap.kernel(lambda v: v.reshape(2, 4), x, name="mat")
    with pytest.raises(ValueError, match="1-D"):
        cap.exchange([(m, 0, 1)])
    with pytest.raises(ValueError, match="self-send"):
        cap.exchange([(y, 1, 1)])
    (r,) = cap.exchange([(y, 0, 1)])
    with pytest.raises(ValueError, match="reception"):
        cap.exchange([(r, 1, 2)])           # raw reception re-sent
    # signature is hashable and kernel-name keyed
    hash(cap.signature())


def test_lower_step_heterogeneous_graph(sess):
    cap = StepCapture()
    x = cap.input((1024,), jnp.float32)
    y = cap.kernel(lambda v: v + 1, x, name="inc")
    (r,) = cap.exchange([(y, 0, 1)], num_chunks=2)
    out = cap.kernel(lambda v: v * 2, r, name="dbl")
    graph, plans = lower_step(cap, sess.engine.plan_group_for,
                              sess.topology.name)
    assert graph.num_compute_nodes == 2
    assert graph.num_copy_nodes == sum(
        len(pa.chunk_bounds()) * pa.route.num_hops
        for p in plans for pa in p.paths)
    assert graph.num_nodes == graph.num_copy_nodes + graph.num_compute_nodes
    assert graph.messages   # messages table carried for def-use validation
    # producer kernel precedes first hop; terminal precedes consumer
    kinds = [type(n).__name__ for n in graph.nodes]
    assert kinds[0] == "ComputeNode" and kinds[-1] == "ComputeNode"
    # explicit out= spec path (axis_index kernels)
    cap2 = StepCapture()
    a = cap2.input((4,), jnp.float32)
    b = cap2.kernel(lambda v: v * jax.lax.axis_index("dev"), a,
                    name="scaled", out=BufferSpec((4,), "float32"))
    assert cap2.buffers[b.buf_id].shape == (4,)


def test_compute_node_cost_model():
    from repro.core.pipelining import COMPUTE_GFLOPS, compute_time_s
    measured = ComputeNode("k", 0, (0,), (1,), flops=1000, cost_ns=500)
    declared = ComputeNode("k", 0, (0,), (1,), flops=10 ** 9)
    assert compute_time_s(measured) == 500 / 1e9
    assert compute_time_s(declared) == pytest.approx(
        1.0 / COMPUTE_GFLOPS)


# ------------------------- kernel adopters (§4.4d) --------------------------

def _kernel_op(cap, name):
    """(operands, results, flops, cost_ns) of one recorded kernel op."""
    (rec,) = [op for op in cap.ops
              if op[0] == "kernel" and op[1] == name]
    return rec[2], rec[3], rec[4], rec[5]


def test_captured_ring_allgather_records_and_prices(sess):
    """The ring all-gather adopter records one ComputeNode with the
    declared gather result spec and the telemetry-median ``cost_ns``.

    Capture/model level only: the remote-DMA kernels need jax's typed
    TPU interpret mode to execute (``pltpu.InterpretParams``), which
    this jax lacks — the same gate that skips their eager sweeps in
    ``test_kernels.py`` — so execution coverage lives there.
    """
    from repro.comm.telemetry import TimelineRecorder
    from repro.kernels.ring_allgather.ops import captured_ring_allgather

    rec = TimelineRecorder(enabled=True)
    for ns in (30_000.0, 40_000.0, 50_000.0):
        rec.record_kernel("ring_allgather", ns)
    n = sess.engine.num_devices
    rows, f = 2, 4
    cap = StepCapture()
    x = cap.input((rows, f), jnp.float32)
    out = captured_ring_allgather(cap, x, n, telemetry=rec)
    # gathered (n*rows, f) result spec, wire work (flops 0), measured ns
    assert cap.buffers[out.buf_id].shape == (n * rows, f)
    operands, results, flops, cost_ns = _kernel_op(cap, "ring_allgather")
    assert operands == (x.buf_id,) and results == (out.buf_id,)
    assert flops == 0 and cost_ns == 40_000
    assert callable(cap.kernels["ring_allgather"])


def test_captured_multipath_dma_lowers_into_mixed_graph(sess):
    """The DMA adopter's ComputeNode coexists with ``cap.exchange``
    copies in one lowered heterogeneous graph, and the lane model
    prices its measured duration on the compute lane."""
    from repro.comm import PathPlanner
    from repro.comm.passes import apply_schedule
    from repro.comm.telemetry import TimelineRecorder
    from repro.core.pipelining import compute_time_s

    rec = TimelineRecorder(enabled=True)
    rec.record_kernel("multipath_dma", 25_000.0)
    n = sess.engine.num_devices
    nelems = 256
    planner = PathPlanner(sess.topology, multipath_threshold=64)
    plan = planner.plan(0, 2, nelems * 4, max_paths=2, num_chunks=2,
                        granularity=4)

    def plan_group_fn(specs, *, max_paths=None, num_chunks=None):
        from repro.comm import TransferRequest
        reqs = [TransferRequest(s, d, ne * 4, granularity=4)
                for (s, d, ne, _) in specs]
        return planner.plan_group(reqs, max_paths=max_paths,
                                  include_host=False,
                                  num_chunks=num_chunks)

    from repro.kernels.multipath_dma.ops import captured_multipath_dma
    cap = StepCapture()
    x = cap.input((nelems,), jnp.float32)
    y = captured_multipath_dma(cap, x, plan, n, telemetry=rec)
    cap.exchange([(y, 0, 1)], num_chunks=2)
    graph, _ = lower_step(cap, plan_group_fn, sess.topology.name)
    assert graph.num_compute_nodes == 1 and graph.num_copy_nodes > 0
    (node,) = [nd for nd in graph.nodes if hasattr(nd, "kernel")]
    assert node.kernel == "multipath_dma" and node.cost_ns == 25_000
    # the stamped measurement is what the lane model charges
    assert compute_time_s(node, sess.topology) == pytest.approx(25e-6)
    # a reorder-only schedule keeps the node multiset (§2.2 contract)
    scheduled, chosen = apply_schedule(graph, "overlap", sess.topology)
    assert chosen == "overlap"
    assert scheduled.num_nodes == graph.num_nodes
    assert scheduled.num_compute_nodes == graph.num_compute_nodes


def test_adopters_stamp_measured_cost_ns():
    """A telemetry recorder with per-kernel measurements prices the
    adopter's ComputeNode by the recorded median (§4.4d close-the-loop);
    without a recorder the declared-FLOPs fallback stands."""
    from repro.comm.telemetry import TimelineRecorder
    from repro.kernels.flash_attention.ops import (attention_flops,
                                                   captured_flash_attention)

    rec = TimelineRecorder(enabled=True)
    for ns in (4_000.0, 5_000.0, 6_000.0):
        rec.record_kernel("flash_attention", ns)
    cap = StepCapture()
    q = cap.input((1, 2, 8, 8), jnp.float32)
    k = cap.input((1, 2, 8, 8), jnp.float32)
    v = cap.input((1, 2, 8, 8), jnp.float32)
    out = captured_flash_attention(cap, q, k, v, telemetry=rec)
    _, _, flops, cost_ns = _kernel_op(cap, "flash_attention")
    assert cost_ns == 5_000                  # the recorded median
    assert flops == attention_flops((1, 2, 8, 8), (1, 2, 8, 8))
    assert cap.buffers[out.buf_id].shape == (1, 2, 8, 8)

    cold = StepCapture()
    q2 = cold.input((1, 2, 8, 8), jnp.float32)
    captured_flash_attention(cold, q2, q2, q2)
    assert _kernel_op(cold, "flash_attention")[3] == 0


# ------------------- overlap acceptance on captured graphs ------------------

def _resolve_graph(sess_like, schedule):
    from repro.core.halo import make_captured_jacobi_step
    step = make_captured_jacobi_step(sess_like, 8, 12, schedule=schedule)
    return step.resolve().graph


def test_overlap_hides_copies_on_captured_jacobi(dev_mesh):
    """ACCEPTANCE: on the captured Jacobi graph the overlap schedule's
    lane makespan is strictly below critical_path's serialized-chain
    makespan — modeled copy time is hidden behind the sweep."""
    from repro.core.pipelining import scheduled_time_s

    ov_sess = CommSession(CommConfig(multipath_threshold=64), mesh=dev_mesh)
    cp_sess = CommSession(CommConfig(multipath_threshold=64), mesh=dev_mesh)
    ov = _resolve_graph(ov_sess, "overlap")
    cp = _resolve_graph(cp_sess, "critical_path")
    lane = scheduled_time_s(ov, ov_sess.topology, mode="lanes")
    serialized = scheduled_time_s(cp, cp_sess.topology, mode="serialized")
    assert lane < serialized                 # strictly hides copy time


def test_overlap_hides_copies_on_captured_dp_train_graph():
    """ACCEPTANCE: same strict inequality on the captured DP-train mixed
    graph (grad → multipath all-reduce → update) in the launch-bound
    regime, priced model-only like the CI overlap gate."""
    from repro.comm import PathPlanner, TransferRequest
    from repro.comm.capture import captured_psum
    from repro.comm.passes import apply_schedule
    from repro.core import Topology
    from repro.core.pipelining import scheduled_time_s

    ndev, nelems = 4, 1 << 10
    topo = Topology.full_mesh(ndev, with_host=False)
    planner = PathPlanner(topo, multipath_threshold=256)

    def plan_group_fn(specs, *, max_paths=None, num_chunks=None):
        reqs = [TransferRequest(s, d, ne * 4, granularity=4)
                for (s, d, ne, _) in specs]
        return planner.plan_group(reqs, max_paths=max_paths,
                                  include_host=False, num_chunks=num_chunks)

    cap = StepCapture()
    x = cap.input((nelems,), jnp.float32)
    g = cap.kernel(lambda v: v * 2.0, x, name="grad", flops=6 * nelems)
    tot = captured_psum(cap, g, ndev, num_chunks=2, name="gradsum")
    cap.kernel(lambda t, v: t / ndev + v, tot, x, name="update",
               flops=10 * nelems)
    graph, _ = lower_step(cap, plan_group_fn, topo.name)

    ov, _ = apply_schedule(graph, "overlap", topo)
    cp, _ = apply_schedule(graph, "critical_path", topo)
    lane = scheduled_time_s(ov, topo, mode="lanes")
    serialized = scheduled_time_s(cp, topo, mode="serialized")
    assert lane < serialized


# ------------------------- captured decode step -----------------------------

def test_captured_decode_step_overlaps_kv_migration(sess):
    """Flagship overlap adopter: ONE dispatch, attention numerics match
    the reference, the KV chunk lands on dst, and the lane model shows
    copy time hidden behind the attention kernel."""
    from repro.core.pipelining import hidden_copy_time_s
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.serving.engine import make_captured_decode_step

    n = sess.engine.num_devices
    batch, heads, kv_len, head_dim, kv_chunk = 1, 2, 16, 8, 4096
    step = make_captured_decode_step(
        sess, batch=batch, heads=heads, kv_len=kv_len, head_dim=head_dim,
        kv_chunk=kv_chunk, src=0, dst=2, schedule="overlap")
    rng = np.random.default_rng(3)
    shp = (n, batch, heads, kv_len, head_dim)
    q = rng.random(shp).astype(np.float32)
    k = rng.random(shp).astype(np.float32)
    v = rng.random(shp).astype(np.float32)
    kv = rng.random((n, kv_chunk)).astype(np.float32)
    attn, new_kv = step(q, k, v, kv)
    assert sess.stats()["dispatches"] == 1

    for d in range(n):                       # per-device attention
        ref = attention_ref(jnp.asarray(q[d]), jnp.asarray(k[d]),
                            jnp.asarray(v[d]), causal=True)
        np.testing.assert_allclose(np.asarray(attn)[d], np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
    expect = kv.copy()
    expect[2] = kv[0]                        # the migrated chunk
    np.testing.assert_allclose(np.asarray(new_kv), expect, rtol=1e-6)

    # the lane model prices the migration copies behind attention
    graph = step.resolve().graph
    assert hidden_copy_time_s(graph, sess.topology) > 0.0
