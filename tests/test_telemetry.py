"""Dispatch telemetry: recorder semantics, stage attribution, and the
windowed-stats satellite (DESIGN.md §4.4c).

Acceptance criteria exercised here (ISSUE 6):

* ``TimelineRecorder`` is off by default, toggles via
  ``REPRO_MP_TELEMETRY``, and ``record`` is a no-op while disabled,
* the ring buffer retains the newest ``capacity`` samples and counts
  drops — unbounded runs cannot grow memory,
* a telemetry-enabled session attributes wall time per dispatch stage:
  slow-path samples carry plan/lower/schedule/compile time, fast-path
  hits carry zeros there (the §2.3 fast path skips those stages),
* ``stats(reset=True)`` rewinds the measurement window — lifecycle
  launch/staging counters, cache hit/miss counters — without touching
  build timings or recorded telemetry samples.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, CommSession
from repro.comm.telemetry import (DEFAULT_CAPACITY, STAGES, TELEMETRY_ENV,
                                  DispatchSample, StageTimings,
                                  TimelineRecorder)
from repro.core import Topology


def _sample(i: int = 0, **stage_ns) -> DispatchSample:
    stages = StageTimings(**stage_ns)
    route = ((((0, 1),), 1024 + i, 2),)
    return DispatchSample(routes=(route,), nbytes=1024 + i, num_nodes=2,
                          window=1, schedule="round_robin", stages=stages,
                          fastpath_hit=False)


def _session(**cfg):
    topo = Topology.full_mesh(4, with_host=False)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("dev",))
    return CommSession(CommConfig(multipath_threshold=64, **cfg),
                       mesh=mesh, topology=topo)


# ------------------------- recorder semantics -------------------------------

def test_recorder_disabled_by_default(monkeypatch):
    monkeypatch.delenv(TELEMETRY_ENV, raising=False)
    rec = TimelineRecorder()
    assert not rec.enabled
    rec.record(_sample())
    assert len(rec) == 0 and rec.samples() == ()
    assert rec.stats() == {"enabled": False,
                           "capacity": DEFAULT_CAPACITY,
                           "retained": 0, "recorded": 0, "dropped": 0}


@pytest.mark.parametrize("value,expect", [
    ("1", True), ("on", True), ("0", False), ("false", False), ("", False)])
def test_recorder_env_toggle(monkeypatch, value, expect):
    monkeypatch.setenv(TELEMETRY_ENV, value)
    assert TimelineRecorder().enabled is expect
    # explicit argument always wins over the environment
    assert TimelineRecorder(enabled=not expect).enabled is (not expect)


def test_ring_buffer_bounds_memory():
    rec = TimelineRecorder(capacity=4, enabled=True)
    for i in range(10):
        rec.record(_sample(i))
    assert len(rec) == 4
    assert [s.nbytes for s in rec.samples()] == [1030, 1031, 1032, 1033]
    st = rec.stats()
    assert st == {"enabled": True, "capacity": 4, "retained": 4,
                  "recorded": 10, "dropped": 6}
    rec.clear()
    assert len(rec) == 0
    assert rec.stats()["recorded"] == 0


def test_recorder_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        TimelineRecorder(capacity=0)


def test_stage_timings_cover_every_stage():
    st = StageTimings(plan_ns=1, lower_ns=2, schedule_ns=3, compile_ns=4,
                     staging_ns=5, launch_ns=6, execute_ns=7)
    d = st.as_dict()
    assert tuple(d) == STAGES
    assert st.total_ns == sum(d.values()) == 28


def test_dispatch_sample_derived_views():
    s = _sample(launch_ns=2_000, execute_ns=3_000)
    assert s.signature == (s.routes, 1, "round_robin", ())
    assert s.num_paths == 1
    assert s.links == ((0, 1),)
    assert s.measured_s == pytest.approx(5e-6)


# ------------------------- session integration ------------------------------

def test_session_attributes_stage_time(monkeypatch):
    monkeypatch.delenv(TELEMETRY_ENV, raising=False)
    sess = _session(telemetry=True)
    msg = jnp.arange(4096, dtype=jnp.float32)
    for _ in range(3):
        jax.block_until_ready(sess.send(msg, 0, 1, num_chunks=2))
    samples = sess.telemetry.samples()
    assert len(samples) == 3
    cold, warm = samples[0], samples[-1]
    # slow path pays plan/lower/compile; timings are wall time, nonzero
    assert not cold.fastpath_hit
    assert cold.stages.plan_ns > 0
    assert cold.stages.lower_ns > 0
    assert cold.stages.compile_ns > 0
    assert cold.stages.launch_ns > 0
    # fast-path hit skips every setup stage (§2.3) but still measures
    # staging/launch/execute
    assert warm.fastpath_hit
    assert warm.stages.plan_ns == warm.stages.lower_ns == 0
    assert warm.stages.schedule_ns == warm.stages.compile_ns == 0
    assert warm.stages.launch_ns > 0
    assert warm.nbytes == 4096 * 4
    assert warm.num_nodes == cold.num_nodes
    st = sess.stats()
    assert st["telemetry"]["recorded"] == 3
    assert st["calibration"] == {"active": False}


def test_session_telemetry_off_records_nothing(monkeypatch):
    monkeypatch.delenv(TELEMETRY_ENV, raising=False)
    sess = _session()
    jax.block_until_ready(sess.send(jnp.arange(4096, dtype=jnp.float32),
                                    0, 1))
    assert len(sess.telemetry) == 0
    assert sess.stats()["telemetry"]["enabled"] is False


def test_config_env_wiring(monkeypatch):
    monkeypatch.setenv(TELEMETRY_ENV, "1")
    monkeypatch.setenv("REPRO_MP_TELEMETRY_CAPACITY", "16")
    monkeypatch.setenv("REPRO_MP_PROFILE_DIR", "/tmp/profiles")
    cfg = CommConfig.from_env()
    assert cfg.telemetry is True
    assert cfg.telemetry_capacity == 16
    assert cfg.profile_dir == "/tmp/profiles"
    with pytest.raises(ValueError, match="telemetry_capacity"):
        CommConfig(telemetry_capacity=0)


# ------------------------- windowed stats (satellite) -----------------------

def test_stats_reset_rewinds_window_not_build_costs(monkeypatch):
    monkeypatch.delenv(TELEMETRY_ENV, raising=False)
    sess = _session(telemetry=True)
    msg = jnp.arange(4096, dtype=jnp.float32)
    for _ in range(4):
        jax.block_until_ready(sess.send(msg, 0, 1))
    st = sess.stats(reset=True)
    assert st["dispatches"] == 4
    assert st["fastpath"]["hits"] == 3
    # the reset call itself reported the pre-reset window…
    st2 = sess.stats()
    # …and the new window starts from zero
    assert st2["dispatches"] == 0
    assert st2["fastpath"]["hits"] == st2["fastpath"]["misses"] == 0
    assert st2["cache"]["hits"] == st2["cache"]["misses"] == 0
    assert st2["fastpath"]["staging_ns"] == 0
    # build timings survive: the compiled plan still knows its build cost
    (compiled,) = sess.cache._store.values()
    assert compiled.lifecycle.build_ns > 0
    assert compiled.lifecycle.launches == 0       # windowed counter rewound
    # telemetry samples are NOT dropped by a stats reset (explicit clear)
    assert len(sess.telemetry) == 4
    # window accumulates again after the reset
    jax.block_until_ready(sess.send(msg, 0, 1))
    assert sess.stats()["dispatches"] == 1


# ------------------- per-kernel execute channel (§4.4d) ---------------------

def test_record_kernel_noop_while_disabled(monkeypatch):
    monkeypatch.delenv(TELEMETRY_ENV, raising=False)
    rec = TimelineRecorder()
    rec.record_kernel("flash_attention", 1_000.0)
    assert rec.kernel_samples() == {}
    assert rec.kernel_cost_ns("flash_attention") == 0.0


def test_record_kernel_aggregates_and_bounds():
    rec = TimelineRecorder(capacity=4, enabled=True)
    for ns in (100.0, 200.0, 300.0, 400.0, 500.0):
        rec.record_kernel("attn", ns)
    rec.record_kernel("sweep", 50.0)
    # per-kernel ring keeps the newest ``capacity`` samples
    assert rec.kernel_samples() == {"attn": (200.0, 300.0, 400.0, 500.0),
                                    "sweep": (50.0,)}
    assert rec.kernel_cost_ns("attn") == pytest.approx(350.0)  # median
    assert rec.kernel_cost_ns("sweep") == 50.0
    assert rec.kernel_cost_ns("unmeasured") == 0.0
    # dispatch-sample stats() schema is untouched by the kernel channel
    assert rec.stats() == {"enabled": True, "capacity": 4, "retained": 0,
                           "recorded": 0, "dropped": 0}


def test_record_kernel_ignores_nonpositive_and_clears():
    rec = TimelineRecorder(capacity=4, enabled=True)
    rec.record_kernel("attn", 0.0)
    rec.record_kernel("attn", -5.0)
    assert rec.kernel_samples() == {}
    rec.record_kernel("attn", 10.0)
    rec.clear()
    assert rec.kernel_samples() == {}
    assert rec.kernel_cost_ns("attn") == 0.0


def test_lifecycle_reset_window_unit():
    from repro.comm.cache import PlanLifecycle

    lc = PlanLifecycle(trace_ns=10, lower_ns=20, compile_ns=30,
                       num_nodes=7)
    lc.launches = 5
    lc.total_launch_ns = 500
    lc.staging_ns = 50
    lc.fastpath_hits = 3
    lc.reset_window()
    assert (lc.launches, lc.total_launch_ns, lc.staging_ns,
            lc.fastpath_hits) == (0, 0, 0, 0)
    # one-time build costs and structure survive the window rewind
    assert (lc.trace_ns, lc.lower_ns, lc.compile_ns) == (10, 20, 30)
    assert lc.build_ns == 60 and lc.num_nodes == 7
