"""Halo exchange + distributed Jacobi (the paper's application, §5.4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core.halo import halo_exchange_ring, jacobi_step
from repro.kernels.jacobi import ref as j_ref


def _global_jacobi_ref(u: np.ndarray) -> np.ndarray:
    """Single-device reference sweep with Dirichlet-zero boundary."""
    ext = np.pad(u, ((0, 0), (1, 1)))
    return np.asarray(j_ref.jacobi_sweep_ref(jnp.asarray(ext)))


@pytest.mark.parametrize("multipath", [False, True])
def test_halo_exchange(dev_mesh, multipath):
    n = 8
    rng = np.random.RandomState(0)
    left = jnp.asarray(rng.randn(n, 4, 6), jnp.float32)
    right = jnp.asarray(rng.randn(n, 4, 6), jnp.float32)

    def body(l, r):
        lh, rh = halo_exchange_ring(l[0], r[0], "dev",
                                    multipath=multipath)
        return lh[None], rh[None]

    f = jax.jit(shard_map(body, mesh=dev_mesh,
                          in_specs=(P("dev"), P("dev")),
                          out_specs=(P("dev"), P("dev")),
                          check_vma=False))
    lh, rh = f(left, right)
    # device i's left halo == right boundary of device i-1
    np.testing.assert_array_equal(np.asarray(lh),
                                  np.roll(np.asarray(right), 1, axis=0))
    np.testing.assert_array_equal(np.asarray(rh),
                                  np.roll(np.asarray(left), -1, axis=0))


@pytest.mark.parametrize("multipath", [False, True])
def test_jacobi_step_matches_global(dev_mesh, multipath):
    rows, w_local, n = 8, 32, 8
    rng = np.random.RandomState(1)
    u_global = rng.randn(rows, w_local * n).astype(np.float32)
    # column partition across devices: (rows, W) -> (n, rows, w_local)
    u_parts = jnp.asarray(
        np.stack(np.split(u_global, n, axis=1)))  # (n, rows, w_local)

    def body(u):
        return jacobi_step(u[0], "dev", multipath=multipath)[None]

    f = jax.jit(shard_map(body, mesh=dev_mesh, in_specs=P("dev"),
                          out_specs=P("dev"), check_vma=False))
    got_parts = np.asarray(f(u_parts))
    got = np.concatenate(list(got_parts), axis=1)
    ref = _global_jacobi_ref(u_global)
    np.testing.assert_allclose(got, ref, atol=1e-6)


def test_jacobi_converges(dev_mesh):
    """Paper §5.4 obs. 6: numerical convergence is unaffected by the
    pipelined/multi-path transfers."""
    rows, w_local, n = 8, 16, 8
    u = jnp.asarray(np.random.RandomState(2).randn(n, rows, w_local),
                    jnp.float32)

    def sweep(u, multipath):
        def body(ul):
            return jacobi_step(ul[0], "dev", multipath=multipath)[None]
        return jax.jit(shard_map(body, mesh=dev_mesh,
                                 in_specs=P("dev"), out_specs=P("dev"),
                                 check_vma=False))(u)

    u_sp, u_mp = u, u
    for _ in range(60):
        u_sp = sweep(u_sp, False)
        u_mp = sweep(u_mp, True)
    np.testing.assert_allclose(np.asarray(u_sp), np.asarray(u_mp),
                               atol=1e-6)
    # Dirichlet-zero problem: the iteration contracts toward zero
    assert float(jnp.max(jnp.abs(u_sp))) < float(jnp.max(jnp.abs(u)))


def test_halo_exchange_group_matches_ring():
    """The driver-level group halo exchange (one fused launch for all 2n
    boundary messages) matches the ring-shift semantics."""
    from repro.comm import CommConfig, CommSession
    from repro.core import Topology
    from repro.core.halo import halo_exchange_group

    n = 8
    sess = CommSession(CommConfig(multipath_threshold=64),
                       topology=Topology.full_mesh(n, with_host=False))
    blocks = jnp.asarray(np.random.RandomState(3).randn(n, 4, 6), jnp.float32)
    before = sess.stats()
    lh, rh = halo_exchange_group(sess, blocks)
    after = sess.stats()
    assert after["dispatches"] - before["dispatches"] == 1   # ONE launch
    right_b, left_b = np.asarray(blocks[:, :, -1:]), np.asarray(
        blocks[:, :, :1])
    np.testing.assert_array_equal(np.asarray(lh), np.roll(right_b, 1, axis=0))
    np.testing.assert_array_equal(np.asarray(rh), np.roll(left_b, -1, axis=0))
