"""Test harness config.

Multipath-transfer and collective tests need a handful of devices; we give
the CPU platform 8 (NOT 512 — the production-mesh dry-run manages its own
device count in its own process, per the launcher contract).
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

import pytest  # noqa: E402

from repro.compat import make_mesh  # noqa: E402
from repro.core import HOST, Link, Topology  # noqa: E402


@pytest.fixture(scope="session")
def dev_mesh():
    """1-D 8-device mesh used by transfer-engine tests."""
    return jax.sharding.Mesh(jax.devices(), ("dev",))


@pytest.fixture(scope="session")
def dp_tp_mesh():
    """2-D (data=2, model=4) mesh used by model-sharding tests."""
    return make_mesh((2, 4), ("data", "model"))


# -- shared topology fixture library ----------------------------------------
# Topologies are mutable (add/remove_link, calibration, node assignment),
# so fixtures default to function scope: each test gets a fresh instance.
# ``mesh8`` is module-scoped because module-scoped planner/session
# fixtures depend on it — tests that mutate a topology build their own.

@pytest.fixture
def beluga4():
    """The paper's Beluga node: 4-GPU NVLink full mesh + PCIe host path."""
    return Topology.full_mesh(4)


@pytest.fixture
def mesh4():
    """4-GPU NVLink full mesh without a host path."""
    return Topology.full_mesh(4, with_host=False, name="mesh4")


@pytest.fixture(scope="module")
def mesh8():
    """8-GPU NVLink full mesh without a host path (engine-sized)."""
    return Topology.full_mesh(8, with_host=False, name="mesh8")


@pytest.fixture
def torus4x4():
    """TPU-style 4×4 ICI torus (16 chips)."""
    return Topology.torus2d(4, 4)


def make_bridge_topology() -> Topology:
    """3 GPUs + host where the only alternative 0→1 path stages mid-route
    through the host: 0↔1 (direct), 0↔2, 2↔HOST, HOST↔1. The detour
    (0,2),(2,HOST),(HOST,1) records via=2, so a via-only executability
    check misses the host hop."""
    gb = 25.0
    links = []
    for a, b in ((0, 1), (0, 2)):
        links += [Link(a, b, "nvlink", gb), Link(b, a, "nvlink", gb)]
    links += [Link(2, HOST, "pcie", 12.0), Link(HOST, 2, "pcie", 12.0),
              Link(HOST, 1, "pcie", 12.0), Link(1, HOST, "pcie", 12.0)]
    return Topology(3, links, name="bridge3")


@pytest.fixture
def bridge3():
    """Host-bridged 3-GPU topology (see :func:`make_bridge_topology`)."""
    return make_bridge_topology()


@pytest.fixture
def two_island():
    """Hierarchical 2-island × 4-GPU topology (NVLink islands + one
    inter-node link pair per island pair)."""
    return Topology.hierarchical(2, 4, name="two_island")
