"""Test harness config.

Multipath-transfer and collective tests need a handful of devices; we give
the CPU platform 8 (NOT 512 — the production-mesh dry-run manages its own
device count in its own process, per the launcher contract).
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

import pytest  # noqa: E402

from repro.compat import make_mesh  # noqa: E402


@pytest.fixture(scope="session")
def dev_mesh():
    """1-D 8-device mesh used by transfer-engine tests."""
    return jax.sharding.Mesh(jax.devices(), ("dev",))


@pytest.fixture(scope="session")
def dp_tp_mesh():
    """2-D (data=2, model=4) mesh used by model-sharding tests."""
    return make_mesh((2, 4), ("data", "model"))
