"""End-to-end training driver: a ~100M-param SmolLM-family model on the
synthetic pipeline, with checkpointing and straggler detection.

Defaults are sized for this CPU container (a few minutes); on real
hardware raise --steps/--batch/--seq (the identical builder lowers the
full assigned configs in the dry-run).

Run:  PYTHONPATH=src python examples/train_smollm.py --steps 300

``--manual-collectives`` switches gradient synchronization from XLA's
auto-sharded collectives to explicit data parallelism through a
``repro.comm.CommSession`` (bidirectional-ring multipath all-reduce).

``--captured-step`` goes one further (DESIGN §2.4): the whole training
step — grad compute, multipath ring all-reduce, optimizer update — is
captured as ONE heterogeneous transfer graph via ``session.capture``,
so each step is exactly one engine dispatch (printed at the end).
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.comm import CommSession
from repro.configs import get_config
from repro.data import DataConfig, SyntheticDataset
from repro.optim import OptimConfig
from repro.runtime import StragglerDetector
from repro.training import (TrainStepConfig, init_state,
                            make_captured_dp_train_step,
                            make_dp_train_step, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--hundred-m", action="store_true",
                    help="full ~100M params (slow on CPU); default is a "
                         "~4M-param config with identical structure")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_smollm_ckpt")
    ap.add_argument("--manual-collectives", action="store_true",
                    help="data-parallel grads via the CommSession's "
                         "multipath collectives instead of auto-sharding")
    ap.add_argument("--captured-step", action="store_true",
                    help="capture the whole train step (grads + ring "
                         "all-reduce + update) as ONE graph: one engine "
                         "dispatch per step (DESIGN §2.4)")
    args = ap.parse_args()

    base = get_config("smollm_360m")
    if args.hundred_m:
        cfg = dataclasses.replace(
            base, name="smollm_100m", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32768, dtype="float32", remat="none", fsdp=False)
    else:
        cfg = dataclasses.replace(
            base.reduced(), name="smollm_mini", num_layers=4,
            d_model=128, d_ff=512, vocab_size=4096)
    n = cfg.param_count()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    opt = OptimConfig(learning_rate=3e-3,
                      warmup_steps=max(1, args.steps // 20),
                      total_steps=args.steps)
    comm = None
    state = init_state(cfg, opt)
    ds = SyntheticDataset(cfg, DataConfig(seq_len=args.seq,
                                          global_batch=args.batch))
    if args.captured_step:
        comm = CommSession()
        batch0 = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
        step_fn = make_captured_dp_train_step(
            cfg, TrainStepConfig(), opt, comm, state, batch0)
        print(f"captured DP step over {comm.num_devices} devices: "
              f"grads + ring all-reduce + update as ONE graph "
              f"(one dispatch per step)")
    elif args.manual_collectives:
        comm = CommSession()
        step_fn = jax.jit(make_dp_train_step(cfg, TrainStepConfig(), opt,
                                             comm),
                          donate_argnums=(0,))
        print(f"manual DP over {comm.num_devices} devices "
              f"(policy={comm.policy.name})")
    else:
        step_fn = jax.jit(make_train_step(cfg, TrainStepConfig(), opt),
                          donate_argnums=(0,))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    straggler = StragglerDetector()
    t_start = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        t0 = time.time()
        state, m = step_fn(state, batch)
        loss = float(m["loss"])
        if straggler.observe(step, time.time() - t0):
            print(f"  straggler at step {step}")
        if step % max(1, args.steps // 15) == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"lr {float(m['lr']):.2e}")
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, state)
    ckpt.save(args.steps, state)
    ckpt.wait()
    print(f"done in {time.time()-t_start:.1f}s; "
          f"checkpoints in {args.ckpt_dir}")
    if args.captured_step:
        g = comm.stats()["graph"]
        print(f"captured-step accounting: {comm.stats()['dispatches']} "
              f"dispatches for {args.steps} steps; compiled "
              f"{g['copy_nodes_compiled']} copy + "
              f"{g['compute_nodes_compiled']} compute nodes")


if __name__ == "__main__":
    main()
