"""The paper's application (§5.4): distributed Jacobi solver with
multi-path halo exchange.

Run:  PYTHONPATH=src python examples/jacobi_multipath.py [--iters 200]

``--captured`` additionally runs the whole-iteration capture mode
(DESIGN §2.4): sweep + halo exchange recorded as ONE heterogeneous
transfer graph via ``session.capture``, so every iteration is exactly
one engine dispatch (the script prints the dispatch count to prove it).
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.halo import jacobi_step, make_captured_jacobi_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--rows", type=int, default=8)
    ap.add_argument("--cols-per-rank", type=int, default=4096)
    ap.add_argument("--captured", action="store_true",
                    help="also run the §2.4 whole-iteration capture: "
                         "sweep + exchange as ONE graph, one dispatch "
                         "per iteration")
    ap.add_argument("--schedule", default=None,
                    help="chunk-interleaving schedule for the captured "
                         "graph (round_robin/depth_first/critical_path/"
                         "auto)")
    args = ap.parse_args()

    mesh = jax.sharding.Mesh(jax.devices(), ("dev",))
    n = len(jax.devices())
    rng = np.random.RandomState(0)
    u0 = jnp.asarray(rng.randn(n, args.rows, args.cols_per_rank),
                     jnp.float32)

    def solver(multipath):
        def local(u):
            def sweep(u, _):
                return jacobi_step(u, "dev", multipath=multipath), None
            u, _ = jax.lax.scan(sweep, u[0], None, length=args.iters)
            return u[None]
        return jax.jit(shard_map(local, mesh=mesh, in_specs=P("dev"),
                                 out_specs=P("dev"), check_vma=False))

    for multipath in (False, True):
        f = solver(multipath)
        u = jax.block_until_ready(f(u0))   # compile + run once
        t0 = time.perf_counter()
        u = jax.block_until_ready(f(u0))
        dt = time.perf_counter() - t0
        resid = float(jnp.max(jnp.abs(u)))
        tag = "multipath" if multipath else "single-path"
        print(f"{tag:12s}: {args.iters} iters in {dt:.3f}s "
              f"({dt / args.iters * 1e3:.2f} ms/iter), max|u|={resid:.4f}")

    if args.captured:
        from repro.comm import CommSession

        session = CommSession(mesh=mesh)
        captured = make_captured_jacobi_step(
            session, args.rows, args.cols_per_rank,
            schedule=args.schedule)
        entry = captured.resolve()      # lower + schedule + compile once
        g = entry.graph
        jax.block_until_ready(captured(u0)[0])       # warm launch
        session.stats(reset=True)
        u = u0
        t0 = time.perf_counter()
        for _ in range(args.iters):
            u = captured(u, block=False)[0]
        u = jax.block_until_ready(u)
        dt = time.perf_counter() - t0
        dispatches = session.stats()["dispatches"]
        resid = float(jnp.max(jnp.abs(u)))
        print(f"{'captured':12s}: {args.iters} iters in {dt:.3f}s "
              f"({dt / args.iters * 1e3:.2f} ms/iter), max|u|={resid:.4f}")
        print(f"  one heterogeneous graph: {g.num_copy_nodes} copy + "
              f"{g.num_compute_nodes} compute nodes, schedule="
              f"{entry.schedule}; {dispatches} dispatches for "
              f"{args.iters} iterations (exactly one per step)")
    print("halo exchange over both direct and diagonal (staged) links — "
          "see benchmarks/bench_jacobi.py for the Beluga-model speedups "
          "and benchmarks/bench_step_capture.py for captured vs "
          "uncaptured dispatch cost")


if __name__ == "__main__":
    main()
