"""Quickstart: the unified comm session API (multi-path + plan caching).

One ``CommSession`` owns the topology, the path policy, the planner, and
the compiled-plan cache — every subsystem (training, serving, benchmarks)
drives communication through it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, CommSession
from repro.core import (Topology, build_schedule, effective_bandwidth_gbps,
                        estimate_transfer_time_s)


def main():
    # 1) describe the node: 4 GPUs, NVLink full mesh + PCIe host (Beluga)
    #    and open a session on it (greedy bandwidth-proportional policy)
    sess = CommSession(CommConfig(max_paths=4),
                       topology=Topology.full_mesh(4))
    topo = sess.topology

    # 2) plan a 64 MiB transfer GPU0 -> GPU1
    plan = sess.plan(0, 1, 64 << 20, max_paths=3)
    print(f"plan: {plan.num_paths} paths, {plan.num_nodes} copy nodes "
          f"(policy={sess.policy.name})")
    for pa in plan.paths:
        print(f"  {pa.route.kind:14s} via={pa.route.via} "
              f"share={pa.nbytes >> 20}MiB chunks={pa.num_chunks}")
    print(f"schedule: {len(build_schedule(plan))} chunk tasks")

    # 3) modeled bandwidth: single vs multi-path (paper Fig. 6)
    single = sess.plan(0, 1, 64 << 20, max_paths=1)
    print(f"modeled: single {effective_bandwidth_gbps(single, topo):.0f} "
          f"GB/s -> multipath "
          f"{effective_bandwidth_gbps(plan, topo):.0f} GB/s "
          f"({estimate_transfer_time_s(single, topo) / estimate_transfer_time_s(plan, topo):.2f}x)")

    # 4) the offline tuner (paper §4.4) searches paths × chunks × host
    best = sess.tune(0, 1, 64 << 20)
    print(f"tuned: {best.num_paths} paths, {best.num_nodes} nodes")

    # 5) execute for real on the host-device mesh, twice (cache hit)
    run = CommSession(topology=Topology.full_mesh(8, with_host=False))
    msg = jnp.arange(1 << 20, dtype=jnp.float32)
    out = run.send(msg, 0, 5)
    assert np.array_equal(np.asarray(out), np.asarray(msg))
    run.send(msg, 0, 5)

    # 5b) concurrent messages: one fused transfer group = one compiled
    # launch, planned contention-aware (exchange patterns stay
    # link-disjoint; see DESIGN.md §5)
    fwd, rev = run.exchange([(msg, 0, 5), (msg * 2, 5, 0)])
    assert np.array_equal(np.asarray(rev), np.asarray(msg * 2))
    print(f"fused 2-message exchange OK; dispatches={run.stats()['dispatches']}")

    # 6) collectives ride the same session + plan cache
    x = jnp.asarray(np.random.RandomState(0).randn(8, 16), jnp.float32)
    gathered = run.all_gather(x)
    assert np.allclose(np.asarray(gathered), np.asarray(x))
    print(f"executed transfer + all-gather OK; "
          f"plan cache: {run.stats()['cache']}")
    key, compiled = next(iter(run.cache._store.items()))
    life = compiled.lifecycle
    print(f"lifecycle: trace {life.trace_ns/1e6:.1f}ms, "
          f"lower {life.lower_ns/1e6:.1f}ms, "
          f"instantiate {life.compile_ns/1e6:.1f}ms, "
          f"mean launch {life.mean_launch_ns/1e6:.2f}ms "
          f"({life.launches} launches)")


if __name__ == "__main__":
    main()
