"""Batched serving demo: prefill + KV-cache decode with mixed request
lengths (greedy decoding, reduced llama3 config), plus KV-cache migration
between devices through the comm session (prefill→decode disaggregation).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommSession
from repro.configs import get_config
from repro.models import transformer as tfm
from repro.serving import Request, ServeEngine


def main():
    cfg = get_config("llama3_8b").reduced()
    params = tfm.init_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, max_len=96, kv_chunks=4,
                         comm=CommSession())

    rng = jax.random.key(1)
    requests = []
    for i, (plen, new) in enumerate([(6, 12), (10, 8), (4, 16), (8, 10)]):
        rng, sub = jax.random.split(rng)
        prompt = jax.random.randint(sub, (plen,), 0,
                                    cfg.vocab_size).tolist()
        requests.append(Request(prompt=prompt, max_new_tokens=new))

    t0 = time.time()
    done = engine.generate(requests)
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    for i, r in enumerate(done):
        print(f"req{i}: prompt_len={len(r.prompt)} -> {len(r.out)} new "
              f"tokens: {r.out[:10]}{'...' if len(r.out) > 10 else ''}")
    print(f"{total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s, "
          f"batch of {len(requests)})")

    # KV migration demo: a prefill node hands its cache to a decode node
    # through the session's compiled multi-path plans (cache-hit on repeat).
    plen = max(len(r.prompt) for r in done)
    toks = jnp.asarray(
        [([0] * (plen - len(r.prompt))) + r.prompt for r in done], jnp.int32)
    logits, cache = engine.prefill(toks)
    moved = engine.migrate_kv(cache, src=0, dst=5)
    ok = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(cache),
                             jax.tree.leaves(moved)))
    engine.migrate_kv(cache, src=0, dst=5)   # second round: pure hits
    print(f"KV migration OK={ok}; comm cache: "
          f"{engine.comm.stats()['cache']}")


if __name__ == "__main__":
    main()
